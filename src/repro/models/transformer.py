"""Composable transformer assembly for all six architecture families.

A model is a stack of *blocks*, each block = (sequence mixer, FFN) with
pre-norms and residual connections. The stack is split into:

  * ``prefix``  — explicit leading blocks (e.g. DeepSeek's dense layers),
  * ``blocks``  — N repetitions of ``cfg.layer_pattern`` ("superblocks"),
                  parameters stacked on a leading axis and executed with
                  ``lax.scan`` (compile-time stays flat in depth),
  * ``tail``    — pattern remainder, unrolled (e.g. RecurrentGemma 26 = 3·8+2).

Encoder-decoder models (seamless-m4t) add an ``encoder`` stack whose output
is the ``memory`` consumed by CROSS_ATTN blocks. VLMs receive ``memory``
directly (stubbed vision frontend per the assignment carve-out).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ATTN, CROSS_ATTN, LOCAL_ATTN, MLA_ATTN, MLP,
                                MOE, NONE, RGLRU, SSM, ModelConfig)
from repro.models import attention as A
from repro.models import cache_ref
from repro.models import ffn as F
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.common import (chunked_softmax_xent, dtype_of, embed_init,
                                 init_rms_norm, rms_norm)
from repro.models.mesh_ctx import MeshCtx

PyTree = Any


# ===========================================================================
# Single block
# ===========================================================================
def block_init(key, cfg: ModelConfig, kind: Tuple[str, str], dtype) -> PyTree:
    mixer, ffn = kind
    k1, k2 = jax.random.split(key)
    p: Dict[str, PyTree] = {"mixer_norm": init_rms_norm(cfg.d_model)}
    if mixer in (ATTN, LOCAL_ATTN):
        p["mixer"] = A.attn_init(k1, cfg, dtype)
    elif mixer == CROSS_ATTN:
        p["mixer"] = A.cross_attn_init(k1, cfg, dtype)
    elif mixer == MLA_ATTN:
        p["mixer"] = A.mla_init(k1, cfg, dtype)
    elif mixer == RGLRU:
        p["mixer"] = R.rglru_init(k1, cfg, dtype)
    elif mixer == SSM:
        p["mixer"] = S.ssm_init(k1, cfg, dtype)
    else:
        raise ValueError(mixer)
    if ffn == MLP:
        p["ffn_norm"] = init_rms_norm(cfg.d_model)
        p["ffn"] = F.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    elif ffn == MOE:
        p["ffn_norm"] = init_rms_norm(cfg.d_model)
        p["ffn"] = F.moe_init(k2, cfg, dtype)
    return p


def block_cache_spec(cfg: ModelConfig, kind, batch: int, max_len: int,
                     mem_len: int, dtype, window_override: int = 0):
    """ShapeDtypeStruct pytree for one block's decode cache (or None)."""
    mixer, _ = kind
    if mixer == ATTN:
        return A.attn_cache_spec(cfg, batch, max_len, window_override, dtype)
    if mixer == LOCAL_ATTN:
        w = cfg.sliding_window or cfg.rglru.window
        return A.attn_cache_spec(cfg, batch, max_len, w, dtype)
    if mixer == CROSS_ATTN:
        return A.cross_attn_cache_spec(cfg, batch, mem_len, dtype)
    if mixer == MLA_ATTN:
        return A.mla_cache_spec(cfg, batch, max_len, dtype)
    if mixer == RGLRU:
        return R.rglru_cache_spec(cfg, batch, dtype)
    if mixer == SSM:
        return S.ssm_cache_spec(cfg, batch, dtype)
    raise ValueError(mixer)


def block_apply(params, x, *, cfg: ModelConfig, ctx: MeshCtx, kind,
                mode: str, cache=None, positions=None, memory=None,
                window_override: int = 0, placement=None):
    """Returns (x_out, new_cache, expert_counts[E] or zeros[1]).

    ``placement``: this layer's EPLB slice ``(replica_slots, n_replicas,
    phys_owner)`` from a :class:`~repro.serving.eplb.PlacementTable`
    (decode path; ``None`` ⇒ logical expert routing)."""
    mixer, ffn = kind
    h = rms_norm(x, params["mixer_norm"], cfg.norm_eps)
    if mixer == ATTN:
        y, new_cache = A.attn_apply(params["mixer"], h, cfg=cfg, ctx=ctx,
                                    mode=mode, window=window_override,
                                    cache=cache, positions=positions)
    elif mixer == LOCAL_ATTN:
        w = cfg.sliding_window or cfg.rglru.window
        y, new_cache = A.attn_apply(params["mixer"], h, cfg=cfg, ctx=ctx,
                                    mode=mode, window=w, cache=cache,
                                    positions=positions)
    elif mixer == CROSS_ATTN:
        y, new_cache = A.cross_attn_apply(params["mixer"], h, cfg=cfg,
                                          ctx=ctx, mode=mode, memory=memory,
                                          cache=cache)
    elif mixer == MLA_ATTN:
        y, new_cache = A.mla_apply(params["mixer"], h, cfg=cfg, ctx=ctx,
                                   mode=mode, cache=cache,
                                   positions=positions)
    elif mixer == RGLRU:
        y, new_cache = R.rglru_apply(params["mixer"], h, cfg=cfg, ctx=ctx,
                                     mode=mode, cache=cache)
    elif mixer == SSM:
        y, new_cache = S.ssm_apply(params["mixer"], h, cfg=cfg, ctx=ctx,
                                   mode=mode, cache=cache)
    else:
        raise ValueError(mixer)
    x = x + y

    counts = jnp.zeros((cfg.moe.num_experts or 1,), jnp.float32)
    aux = jnp.zeros((2,), jnp.float32)
    if ffn == MLP:
        h = rms_norm(x, params["ffn_norm"], cfg.norm_eps)
        x = x + F.mlp_apply(params["ffn"], h)
    elif ffn == MOE:
        h = rms_norm(x, params["ffn_norm"], cfg.norm_eps)
        y, moe_aux = F.moe_apply(params["ffn"], h, cfg=cfg, ctx=ctx,
                                 mode=mode, placement=placement)
        x = x + y
        counts = moe_aux["expert_counts"]
        aux = jnp.stack([moe_aux["moe_lb_loss"], moe_aux["moe_z_loss"]])
    return x, new_cache, (aux, counts)


# ===========================================================================
# Model
# ===========================================================================
class Model:
    """Functional model wrapper. All methods are pure and jit-friendly."""

    def __init__(self, cfg: ModelConfig, ctx: MeshCtx,
                 long_context: bool = False):
        self.cfg = cfg
        self.ctx = ctx
        self.dtype = dtype_of(cfg.dtype)
        # long-context serving substitutes a sliding window for global
        # attention (dense archs only; see DESIGN.md §4)
        self.window_override = (cfg.long_context_window
                                if long_context and not
                                cfg.supports_long_context else 0)
        kinds = cfg.layer_kinds()
        np_, nsb, pl = len(cfg.prefix_layers), cfg.num_superblocks, cfg.pattern_len
        self.prefix_kinds = kinds[:np_]
        self.pattern = cfg.layer_pattern
        self.n_sb = nsb
        self.tail_kinds = kinds[np_ + nsb * pl:]

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, key) -> PyTree:
        cfg, dtype = self.cfg, self.dtype
        keys = jax.random.split(key, 8)
        params: Dict[str, PyTree] = {
            "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model),
                                dtype),
            "final_norm": init_rms_norm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(keys[1],
                                           (cfg.d_model, cfg.vocab_size),
                                           dtype)
        if self.prefix_kinds:
            pk = jax.random.split(keys[2], len(self.prefix_kinds))
            params["prefix"] = tuple(
                block_init(k, cfg, kind, dtype)
                for k, kind in zip(pk, self.prefix_kinds))
        if self.n_sb:
            def init_sb(k):
                ks = jax.random.split(k, len(self.pattern))
                return {f"pos{i}": block_init(ks[i], cfg, kind, dtype)
                        for i, kind in enumerate(self.pattern)}
            sb_keys = jax.random.split(keys[3], self.n_sb)
            params["blocks"] = jax.vmap(init_sb)(sb_keys)
        if self.tail_kinds:
            tk = jax.random.split(keys[4], len(self.tail_kinds))
            params["tail"] = tuple(
                block_init(k, cfg, kind, dtype)
                for k, kind in zip(tk, self.tail_kinds))
        if cfg.is_encdec:
            params["encoder"] = self._encoder_init(keys[5])
        if cfg.mtp_num_layers:
            mk = jax.random.split(keys[6], cfg.mtp_num_layers)
            params["mtp"] = tuple(self._mtp_init(k) for k in mk)
        return params

    def _encoder_init(self, key):
        cfg = self.cfg
        ecfg = dataclasses.replace(
            cfg, d_model=cfg.encoder_d_model or cfg.d_model,
            prefix_layers=(), layer_pattern=((ATTN, MLP),),
            num_layers=cfg.encoder_layers)
        ks = jax.random.split(key, cfg.encoder_layers + 1)
        return {
            "blocks": tuple(block_init(k, ecfg, (ATTN, MLP), self.dtype)
                            for k in ks[:-1]),
            "norm": init_rms_norm(ecfg.d_model),
        }

    def _mtp_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        from repro.models.common import dense_init
        return {
            "proj": dense_init(k1, (2 * cfg.d_model, cfg.d_model),
                               self.dtype, 2 * cfg.d_model),
            "norm_h": init_rms_norm(cfg.d_model),
            "norm_e": init_rms_norm(cfg.d_model),
            "block": block_init(k2, cfg, (self.pattern[-1][0], MLP)
                                if self.pattern[-1][0] != CROSS_ATTN
                                else (ATTN, MLP), self.dtype),
        }

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def cache_spec(self, batch: int, max_len: int,
                   mem_len: Optional[int] = None) -> PyTree:
        cfg = self.cfg
        mem_len = mem_len or cfg.num_frontend_tokens
        mk = functools.partial(block_cache_spec, cfg, batch=batch,
                               max_len=max_len, mem_len=mem_len,
                               dtype=self.dtype,
                               window_override=self.window_override)
        spec: Dict[str, PyTree] = {}
        if self.prefix_kinds:
            spec["prefix"] = tuple(mk(kind=k) for k in self.prefix_kinds)
        if self.n_sb:
            def stack(s):
                return jax.ShapeDtypeStruct((self.n_sb,) + s.shape, s.dtype)
            spec["blocks"] = {
                f"pos{i}": jax.tree.map(stack, mk(kind=kind))
                for i, kind in enumerate(self.pattern)}
        if self.tail_kinds:
            spec["tail"] = tuple(mk(kind=k) for k in self.tail_kinds)
        return spec

    def init_cache(self, batch: int, max_len: int,
                   mem_len: Optional[int] = None) -> PyTree:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_spec(batch, max_len, mem_len))

    def mtp_cache_spec(self, batch: int, max_len: int) -> PyTree:
        """Batched decode-state spec for the MTP draft head (§4.6):
        ``"kv"`` — the head's block decode cache (same shapes
        :meth:`mtp_step` writes through the ``CacheRef`` machinery, all
        leaves batch-major like the main cache's single blocks), and
        ``"hidden"`` — the ``[B, 1, d]`` main-model final hidden carried
        across decode iterations as the head's conditioning input."""
        cfg = self.cfg
        kind = (self.pattern[-1][0], MLP)
        if kind[0] == CROSS_ATTN:
            kind = (ATTN, MLP)
        # window_override defaults to 0 to match mtp_step's block_apply
        return {
            "kv": block_cache_spec(cfg, kind, batch, max_len,
                                   cfg.num_frontend_tokens, self.dtype),
            "hidden": jax.ShapeDtypeStruct((batch, 1, cfg.d_model),
                                           self.dtype),
        }

    def init_mtp_cache(self, batch: int, max_len: int) -> PyTree:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.mtp_cache_spec(batch, max_len))

    # ------------------------------------------------------------------
    # core stack application
    # ------------------------------------------------------------------
    def _apply_stack(self, params, x, *, mode, caches=None, positions=None,
                     memory=None, placement=None):
        cfg, ctx = self.cfg, self.ctx
        apply = functools.partial(block_apply, cfg=cfg, ctx=ctx, mode=mode,
                                  positions=positions, memory=memory,
                                  window_override=self.window_override)
        new_caches: Dict[str, PyTree] = {}
        aux_sum = jnp.zeros((2,), jnp.float32)
        counts_list: List[jax.Array] = []
        np_, pl_len = len(self.prefix_kinds), len(self.pattern)

        def layer_placement(layer_idx: int):
            """Per-layer EPLB slice at a PYTHON layer index (prefix/tail
            unrolled sections; the scan slices its own xs)."""
            if placement is None:
                return None
            return placement.layer(layer_idx)

        def get(c, key, i):
            return None if c is None or key not in c else c[key][i]

        def run_unrolled(section, i, kind, x):
            c = get(caches, section, i)
            gl = i if section == "prefix" \
                else np_ + self.n_sb * pl_len + i
            lp = layer_placement(gl)
            if mode in ("decode", "chunk") and c is not None:
                ref = cache_ref.wrap_single(c)
                x, nref, (aux, counts) = apply(params[section][i], x,
                                               kind=kind, cache=ref,
                                               placement=lp)
                nc = cache_ref.unwrap_single(nref)
            else:
                x, nc, (aux, counts) = apply(params[section][i], x,
                                             kind=kind, cache=c,
                                             placement=lp)
            new_caches.setdefault(section, []).append(nc)
            return x, aux, counts

        for i, kind in enumerate(self.prefix_kinds):
            x, aux, counts = run_unrolled("prefix", i, kind, x)
            aux_sum += aux
            counts_list.append(counts)

        # superblock placement slices rearranged [n_sb, pattern_len, ...]
        # and scanned as xs next to the stacked params
        pl_blocks = None
        if placement is not None and self.n_sb:
            sl = slice(np_, np_ + self.n_sb * pl_len)
            pl_blocks = tuple(
                a[sl].reshape((self.n_sb, pl_len) + a.shape[1:])
                for a in (placement.replica_slots, placement.n_replicas,
                          placement.phys_owner))

        if self.n_sb and mode in ("decode", "chunk"):
            # caches are carried (not scanned xs/ys) so that the per-step
            # cache write is an in-place scatter of the new token only
            # (decode) or of the current chunk's slice (chunked prefill).
            def superblock_dec(carry, xs):
                x, aux_acc, cstacks = carry
                sb_params, idx, sb_pl = xs
                cts = []
                for i, kind in enumerate(self.pattern):
                    ref = cache_ref.CacheRef(cstacks[f"pos{i}"], idx)
                    lp = None if sb_pl is None \
                        else tuple(a[i] for a in sb_pl)
                    x, nref, (aux, counts) = apply(sb_params[f"pos{i}"], x,
                                                   kind=kind, cache=ref,
                                                   placement=lp)
                    cstacks = dict(cstacks)
                    cstacks[f"pos{i}"] = nref.stack
                    aux_acc = aux_acc + aux
                    cts.append(counts)
                return (x, aux_acc, cstacks), jnp.stack(cts)

            (x, aux_sum, nc_stack), counts_sb = jax.lax.scan(
                superblock_dec, (x, aux_sum, caches["blocks"]),
                (params["blocks"], jnp.arange(self.n_sb), pl_blocks))
            new_caches["blocks"] = nc_stack
            counts_list.append(counts_sb.sum(axis=(0, 1)))
        elif self.n_sb:
            def superblock(carry, xs):
                x, aux_acc = carry
                sb_params = xs
                ncs = {}
                cts = []
                for i, kind in enumerate(self.pattern):
                    x, nc, (aux, counts) = apply(sb_params[f"pos{i}"], x,
                                                 kind=kind, cache=None)
                    ncs[f"pos{i}"] = nc
                    cts.append(counts)
                    aux_acc = aux_acc + aux
                # drop None cache entries for scan-compatibility
                ncs = {k: v for k, v in ncs.items() if v is not None}
                return (x, aux_acc), (ncs if ncs else None,
                                      jnp.stack(cts))

            body = superblock
            if ctx.remat == "full":
                body = jax.checkpoint(superblock)
            (x, aux_sum), (nc_stack, counts_sb) = jax.lax.scan(
                body, (x, aux_sum), params["blocks"])
            if nc_stack is not None:
                new_caches["blocks"] = nc_stack
            counts_list.append(counts_sb.sum(axis=(0, 1)))

        for i, kind in enumerate(self.tail_kinds):
            x, aux, counts = run_unrolled("tail", i, kind, x)
            aux_sum += aux
            counts_list.append(counts)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        for k in ("prefix", "tail"):
            if k in new_caches:
                new_caches[k] = tuple(new_caches[k])
        counts = (jnp.sum(jnp.stack(
            [c for c in counts_list if c.shape[0] > 1]), axis=0)
            if cfg.has_moe else jnp.zeros((1,), jnp.float32))
        return x, new_caches, aux_sum, counts

    # ------------------------------------------------------------------
    # encoder (audio)
    # ------------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: [B, M, d_enc] stubbed frontend embeddings → memory."""
        cfg = self.cfg
        ecfg = dataclasses.replace(
            cfg, d_model=cfg.encoder_d_model or cfg.d_model)
        x = frames
        for bp in params["encoder"]["blocks"]:
            h = rms_norm(x, bp["mixer_norm"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", h, bp["mixer"]["wq"])
            k = jnp.einsum("bsd,dhk->bshk", h, bp["mixer"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, bp["mixer"]["wv"])
            from repro.models.common import naive_attention
            o = naive_attention(q, k, v, causal=False)
            x = x + jnp.einsum("bshk,hkd->bsd", o, bp["mixer"]["wo"])
            h = rms_norm(x, bp["ffn_norm"], cfg.norm_eps)
            x = x + F.mlp_apply(bp["ffn"], h)
        return rms_norm(x, params["encoder"]["norm"], cfg.norm_eps)

    # ------------------------------------------------------------------
    # public steps
    # ------------------------------------------------------------------
    def _unembed(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _embed(self, params, tokens):
        x = params["embed"][tokens]
        return x.astype(self.dtype)

    def _residual_constraint(self, x, mode):
        ctx = self.ctx
        if mode in ("train", "prefill") and x.shape[1] % max(
                ctx.axis_size(ctx.tp_axis), 1) == 0 and ctx.tp_size > 1:
            # sequence-parallel residual stream
            return jax.lax.with_sharding_constraint(
                x, ctx.sharding(ctx.bspec, ctx.tp_axis, None))
        return x

    def forward_train(self, params, tokens, labels, memory=None,
                      loss_mask=None):
        """tokens/labels: [B, S]. Returns (loss, metrics)."""
        if self.cfg.is_encdec:
            memory = self.encode(params, memory)
        x = self._embed(params, tokens)
        x = self._residual_constraint(x, "train")
        x, _, aux, counts = self._apply_stack(params, x, mode="train",
                                              memory=memory)
        nll, n_tok = chunked_softmax_xent(x, labels, self._unembed(params),
                                          mask=loss_mask)
        loss = nll + aux[0] + aux[1]
        metrics = {"nll": nll, "moe_lb_loss": aux[0], "moe_z_loss": aux[1],
                   "tokens": n_tok, "expert_counts": counts}
        return loss, metrics

    def prefill(self, params, tokens, memory=None, last_pos=None):
        """tokens: [B, S] → (logits at ``last_pos`` (default S-1) [B, V],
        cache). ``last_pos`` supports right-padded serving batches."""
        if self.cfg.is_encdec:
            memory = self.encode(params, memory)
        x = self._embed(params, tokens)
        x = self._residual_constraint(x, "prefill")
        x, caches, _, _ = self._apply_stack(params, x, mode="prefill",
                                            memory=memory)
        if last_pos is None:
            h = x[:, -1]
        else:
            h = x[jnp.arange(x.shape[0]), last_pos]
        logits = jnp.einsum("bd,dv->bv", h.astype(jnp.float32),
                            self._unembed(params).astype(jnp.float32))
        return logits, caches

    def prefill_chunk(self, params, cache, tokens, offset, last_pos):
        """Chunked prefill: run ONE contiguous chunk of a prompt against
        the partially-filled cache buffers in ``cache``.

        ``tokens``: [B, S_chunk] (padded chunk); ``offset``: scalar int32
        absolute position of the chunk's first token (earlier chunks
        populated positions ``< offset``); ``last_pos``: [B] index WITHIN
        the chunk of its last valid token. Returns ``(logits [B, V] at
        the last valid position, new cache)`` — on the final chunk these
        logits equal :meth:`prefill`'s, and the cache's valid region
        (positions ``< prompt_len``) is bit-identical to the monolithic
        prefill cache of the same bucketed length. ``cache`` is the
        full-length buffer pytree from :meth:`init_cache` (batch 1 in
        serving). Global-attention mixers only (ATTN / MLA_ATTN)."""
        assert not self.cfg.is_encdec, "chunked prefill: decoder-only"
        x = self._embed(params, tokens)
        x = self._residual_constraint(x, "prefill")
        x, caches, _, _ = self._apply_stack(params, x, mode="chunk",
                                            caches=cache,
                                            positions=offset)
        h = x[jnp.arange(x.shape[0]), last_pos]
        logits = jnp.einsum("bd,dv->bv", h.astype(jnp.float32),
                            self._unembed(params).astype(jnp.float32))
        return logits, caches

    def decode_step(self, params, cache, tokens, positions, memory=None,
                    placement=None):
        """tokens: [B, 1]; positions: [B]. → (logits [B, V], new cache).

        ``placement``: optional device-resident
        :class:`~repro.serving.eplb.PlacementTable` (leading dim =
        n_layers) — the EPLB data plane each MoE layer routes through."""
        logits, _, new_caches = self.decode_step_hidden(
            params, cache, tokens, positions, memory=memory,
            placement=placement)
        return logits, new_caches

    def decode_step_hidden(self, params, cache, tokens, positions,
                           memory=None, placement=None):
        """:meth:`decode_step` that also returns the final hidden state
        ``[B, 1, d]`` — the MTP draft head's conditioning input. Runs the
        IDENTICAL op sequence as ``decode_step`` (which delegates here),
        so logits stay bit-identical between the two entry points."""
        x = self._embed(params, tokens)
        x, new_caches, _, _ = self._apply_stack(params, x, mode="decode",
                                                caches=cache,
                                                positions=positions,
                                                memory=memory,
                                                placement=placement)
        logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                            self._unembed(params).astype(jnp.float32))
        return logits, x[:, -1:], new_caches

    # ------------------------------------------------------------------
    # MTP draft head (paper §4.6): h' = Block(proj([norm(h); norm(e_next)]))
    # ------------------------------------------------------------------
    def mtp_step(self, params, mtp_index: int, hidden, next_tokens,
                 positions, mtp_cache=None):
        """hidden: [B,1,d] main-model final hidden; next_tokens: [B,1].
        Returns (draft logits [B,V], new hidden [B,1,d], cache)."""
        cfg = self.cfg
        mp = params["mtp"][mtp_index]
        e = self._embed(params, next_tokens)
        h = jnp.concatenate([
            rms_norm(hidden, mp["norm_h"], cfg.norm_eps),
            rms_norm(e, mp["norm_e"], cfg.norm_eps)], axis=-1)
        h = jnp.einsum("bsd,de->bse", h, mp["proj"])
        kind = (self.pattern[-1][0], MLP)
        if kind[0] == CROSS_ATTN:
            kind = (ATTN, MLP)
        if mtp_cache is not None:
            ref = cache_ref.wrap_single(mtp_cache)
            h, nref, _ = block_apply(mp["block"], h, cfg=cfg, ctx=self.ctx,
                                     kind=kind, mode="decode",
                                     cache=ref, positions=positions)
            nc = cache_ref.unwrap_single(nref)
        else:
            h, nc, _ = block_apply(mp["block"], h, cfg=cfg, ctx=self.ctx,
                                   kind=kind, mode="train",
                                   cache=None, positions=positions)
        logits = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                            self._unembed(params).astype(jnp.float32))
        return logits, h, nc


def build_model(cfg: ModelConfig, ctx: MeshCtx,
                long_context: bool = False) -> Model:
    return Model(cfg, ctx, long_context=long_context)
