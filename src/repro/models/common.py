"""Shared building blocks: norms, RoPE, initializers, chunked losses."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def microbatch_sizes(n: int, mb: int) -> Tuple[int, ...]:
    """Split ``n`` rows into ``mb`` contiguous §4.4 ping-pong
    micro-batches (earlier chunks take the remainder). Shared by the
    decode MoE paths in models/ffn.py and core/moe_attn_disagg.py so
    both split batches identically."""
    mb = max(1, min(int(mb), n)) if n else 1
    return tuple(n // mb + (1 if i < n % mb else 0) for i in range(mb))


# ---------------------------------------------------------------------------
# Initializers. Params are plain nested dicts of jnp arrays.
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, in_axis_size: Optional[int] = None):
    """Truncated-normal fan-in init (matches common LLM practice)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int) -> jax.Array:
    # stored as deviation from 1.0 (gemma-style), so zeros init.
    return jnp.zeros((d,), jnp.float32)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]   # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked cross-entropy: never materializes [B, S, vocab].
# ---------------------------------------------------------------------------
def chunked_softmax_xent(
    hidden: jax.Array,          # [B, S, d]
    labels: jax.Array,          # [B, S] int32
    unembed: jax.Array,         # [d, V]
    mask: Optional[jax.Array] = None,   # [B, S] 1.0 = count
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (mean nll, total tokens). Scans over sequence chunks so the
    peak logits buffer is [B, chunk, V] (vocab-shardable by GSPMD)."""
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def chunk_loss(h, y, m):
        logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                            unembed.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * m), jnp.sum(m)

    def body(carry, xs):
        tot, cnt = carry
        h, y, m = xs
        l, c = chunk_loss(h, y, m)
        return (tot + l, cnt + c), None

    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    hs = hidden[:, : n * chunk].reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    ys = labels[:, : n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)
    ms = mask[:, : n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hs, ys, ms))
    if rem:
        l, c = chunk_loss(hidden[:, n * chunk:], labels[:, n * chunk:],
                          mask[:, n * chunk:])
        tot, cnt = tot + l, cnt + c
    return tot / jnp.maximum(cnt, 1.0), cnt


# ---------------------------------------------------------------------------
# Blockwise (flash-style) causal attention in pure JAX.
# Online-softmax over KV blocks: O(S * block) memory instead of O(S^2).
# ---------------------------------------------------------------------------
def blockwise_attention(
    q: jax.Array,                 # [B, S, H, hd]
    k: jax.Array,                 # [B, S, KV, hd]
    v: jax.Array,                 # [B, S, KV, hd]
    *,
    causal: bool = True,
    window: int = 0,              # >0: sliding-window causal
    q_block: int = 512,
    kv_block: int = 512,
    logit_softcap: float = 0.0,
) -> jax.Array:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    vd = v.shape[-1]
    G = H // KV
    scale = 1.0 / np.sqrt(hd)
    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    nq = -(-S // q_block)
    nk = -(-S // kv_block)
    pad_q = nq * q_block - S
    pad_k = nk * kv_block - S
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # [B, nq, qb, KV, G, hd]
    qr = q.reshape(B, nq, q_block, KV, G, hd)
    kr = k.reshape(B, nk, kv_block, KV, hd)
    vr = v.reshape(B, nk, kv_block, KV, vd)

    q_pos = jnp.arange(nq * q_block)
    k_pos = jnp.arange(nk * kv_block)

    def q_body(qi, q_blk):
        # q_blk: [B, qb, KV, G, hd]
        qp = jax.lax.dynamic_slice_in_dim(q_pos, qi * q_block, q_block)

        def kv_body(carry, ki):
            # Additive masking + finite running max (init -1e30): avoids
            # the inf/isfinite select passes, which the dry-run profile
            # showed re-materializing the [b,kv,g,qb,kb] score block in
            # HBM several extra times per (q,kv) pair (Perf iteration A2).
            m, l, acc = carry
            k_blk = jax.lax.dynamic_index_in_dim(kr, ki, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vr, ki, 1, keepdims=False)
            kp = jax.lax.dynamic_slice_in_dim(k_pos, ki * kv_block, kv_block)
            s = jnp.einsum("bqkgd,bpkd->bkgqp", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if logit_softcap > 0:
                s = logit_softcap * jnp.tanh(s / logit_softcap)
            msk = jnp.ones((q_block, kv_block), bool)
            if causal:
                msk &= qp[:, None] >= kp[None, :]
            if window > 0:
                msk &= qp[:, None] - kp[None, :] < window
            msk &= (kp < S)[None, :]
            bias = jnp.where(msk, 0.0, -1e30).astype(jnp.float32)
            s = s + bias[None, None, None]
            new_m = jnp.maximum(m, jnp.max(s, axis=-1))         # finite
            p = jnp.exp(s - new_m[..., None])   # masked -> exp(-1e30) = 0
            corr = jnp.exp(m - new_m)
            new_l = corr * l + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqp,bpkd->bkgqd", p.astype(v_blk.dtype),
                            v_blk, preferred_element_type=jnp.float32)
            new_acc = corr[..., None] * acc + pv
            return (new_m, new_l, new_acc), None

        m0 = jnp.full((B, KV, G, q_block), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_block, vd), jnp.float32)
        if causal:
            # only blocks with k_start <= q_end; conservatively scan all when
            # windowed (skip logic kept simple: scan 0..ki_max)
            ki_max = (qi + 1) * q_block  # exclusive in positions
            nk_eff = (ki_max + kv_block - 1) // kv_block
        else:
            nk_eff = nk
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                      jnp.arange(nk))
        del nk_eff  # masking already enforces causality; scan all for static shape
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # [B, KV, G, qb, vd] -> [B, qb, KV*G, vd]
        return out.transpose(0, 3, 1, 2, 4).reshape(B, q_block, H, vd)

    outs = jax.lax.map(lambda qi: q_body(qi, qr[:, qi]), jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, H, vd)
    return out[:, :S].astype(q.dtype)


def naive_attention(q, k, v, *, causal=True, window: int = 0,
                    logit_softcap: float = 0.0,
                    kv_positions: Optional[jax.Array] = None,
                    q_positions: Optional[jax.Array] = None) -> jax.Array:
    """Reference attention, materializes scores. q:[B,Sq,H,hd] k/v:[B,Sk,KV,hd]."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(hd)
    qr = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgd,bpkd->bkgqp", qr, k,
                   preferred_element_type=jnp.float32) * scale
    if logit_softcap > 0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    qp = q_positions if q_positions is not None else jnp.arange(Sq)
    kp = kv_positions if kv_positions is not None else jnp.arange(k.shape[1])
    msk = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        msk &= qp[:, None] >= kp[None, :]
    if window > 0:
        msk &= qp[:, None] - kp[None, :] < window
    s = jnp.where(msk[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqp,bpkd->bqkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)
