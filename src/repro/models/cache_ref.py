"""In-place cache views for decode.

During decode, per-layer KV/state caches are carried through the layer
scan as one stacked buffer per pattern position, and each layer updates
its slice via a scatter into the stacked buffer. This lets XLA keep the
cache in place inside the while loop (the write per step is just the new
token's KV, not a full cache copy — the difference between ~128 KB and
~67 MB per layer per decode step).

A :class:`CacheRef` is (stacked arrays, layer index). Blocks outside the
scan (prefix/tail) wrap their un-stacked caches with a leading 1.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


class CacheRef(NamedTuple):
    stack: Dict[str, jax.Array]   # each array: [n_layers, ...]
    idx: Any                      # scalar int32 layer index

    def read(self, name: str) -> jax.Array:
        return jax.lax.dynamic_index_in_dim(self.stack[name], self.idx, 0,
                                            keepdims=False)

    def with_stack(self, stack) -> "CacheRef":
        return CacheRef(stack, self.idx)


def wrap_single(cache: Dict[str, jax.Array]) -> CacheRef:
    """Wrap an un-stacked per-layer cache as a 1-deep stack."""
    return CacheRef({k: v[None] for k, v in cache.items()}, 0)


def unwrap_single(ref: CacheRef) -> Dict[str, jax.Array]:
    return {k: v[0] for k, v in ref.stack.items()}
