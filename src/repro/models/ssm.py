"""Mamba-2 block with the SSD (state-space duality) algorithm.

[arXiv:2405.21060]. Train/prefill use the chunked SSD form: within-chunk
computation is an attention-like quadratic over chunk_size, inter-chunk
states are carried by a scan — O(S·Q) memory instead of O(S·N·P) for a
materialized recurrence. Decode is the O(1) recurrent update.

Layout follows the minimal-mamba2 reference: in_proj → (z, x, B, C, dt);
causal depthwise conv over (x, B, C); SSD; gated RMSNorm; out_proj.
ngroups = 1 (B and C shared across heads).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rms_norm, init_rms_norm
from repro.models.mesh_ctx import MeshCtx

Cache = Dict[str, jax.Array]


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = s.num_heads or d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.state_dim
    return d_inner, n_heads, s.head_dim, s.state_dim, conv_dim


def ssm_init(key, cfg: ModelConfig, dtype) -> Dict[str, jax.Array]:
    d = cfg.d_model
    di, h, p, n, conv_dim = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * n + h
    return {
        "in_proj": dense_init(ks[0], (d, in_dim), dtype, d),
        "conv_w": dense_init(ks[1], (cfg.ssm.conv_width, conv_dim), dtype,
                             cfg.ssm.conv_width),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (h,), jnp.float32,
                                       np.log(1e-3), np.log(1e-1))))),
        "norm": init_rms_norm(di),
        "out_proj": dense_init(ks[3], (di, d), dtype, di),
    }


def ssm_cache_spec(cfg: ModelConfig, batch: int, dtype):
    di, h, p, n, conv_dim = ssm_dims(cfg)
    return {
        "state": jax.ShapeDtypeStruct((batch, h, p, n), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm.conv_width - 1,
                                      conv_dim), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 history: Optional[jax.Array] = None):
    """x: [B, S, C]; w: [K, C] depthwise. history: [B, K-1, C] (decode).
    Returns (y [B,S,C], new_history [B, K-1, C])."""
    K = w.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)        # [B, S+K-1, C]
    y = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(K)) + b
    new_hist = xp[:, -(K - 1):] if K > 1 else history
    return jax.nn.silu(y), new_hist


def _segsum(a: jax.Array) -> jax.Array:
    """a: [..., Q] → cumulative decay matrix [..., Q, Q] with
    out[..., i, j] = sum(a[j+1..i]) for i ≥ j, -inf otherwise."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(xh, a, B, C, chunk: int):
    """SSD over full sequence.

    xh: [b, S, h, p] (dt-scaled input); a: [b, S, h] (log decay per step);
    B, C: [b, S, n]. Returns (y [b,S,h,p], final_state [b,h,p,n]).
    """
    b, S, h, p = xh.shape
    n = B.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    assert S % Q == 0, "sequence must be divisible by ssm chunk"
    xh = xh.reshape(b, nc, Q, h, p)
    a = a.reshape(b, nc, Q, h).transpose(0, 1, 3, 2)       # [b,nc,h,Q]
    B_ = B.reshape(b, nc, Q, n)
    C_ = C.reshape(b, nc, Q, n)

    # 1. intra-chunk (attention-like)
    L = jnp.exp(_segsum(a))                                # [b,nc,h,Q,Q]
    scores = jnp.einsum("bcqn,bcpn->bcqp", C_, B_)         # [b,nc,Q,Q]
    y_diag = jnp.einsum("bcqp,bchqp,bcphd->bcqhd",
                        scores, jnp.where(jnp.isfinite(L), L, 0.0)
                        .transpose(0, 1, 2, 3, 4), xh)
    # note: L transposed to [b,nc,h,Q(dst),Q(src)] already matches.

    # 2. chunk-final states
    a_cum = jnp.cumsum(a, axis=-1)                         # [b,nc,h,Q]
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)        # [b,nc,h,Q]
    states = jnp.einsum("bcqn,bchq,bcqhd->bchdn",
                        B_, decay_to_end, xh)              # [b,nc,h,p,n]

    # 3. inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(a_cum[..., -1])                  # [b,nc,h]

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                                  # emit PREVIOUS

    init = jnp.zeros((b, h, p, n), xh.dtype)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # [b,nc,h,p,n]

    # 4. inter-chunk contribution
    state_decay = jnp.exp(a_cum)                           # decay 0..q
    y_off = jnp.einsum("bcqn,bchq,bchdn->bcqhd",
                       C_, state_decay, prev_states)
    y = (y_diag + y_off).reshape(b, S, h, p)
    return y, final


def ssm_apply(
    params, x: jax.Array, *, cfg: ModelConfig, ctx: MeshCtx, mode: str,
    cache: Optional[Cache] = None,
) -> Tuple[jax.Array, Optional[Cache]]:
    di, h, p, n, conv_dim = ssm_dims(cfg)
    Bsz, S, d = x.shape
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xr, Bc, Cc, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xr, Bc, Cc], axis=-1)
    is_ref = cache is not None and hasattr(cache, "read")
    hist = ((cache.read("conv") if is_ref else cache["conv"])
            if mode == "decode" else None)
    conv_out, new_hist = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], hist)
    xr, Bc, Cc = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])              # [B,S,h]
    A = -jnp.exp(params["a_log"])                          # [h]
    a_log_step = dt * A                                    # [B,S,h]
    xh = xr.reshape(Bsz, S, h, p).astype(jnp.float32) * dt[..., None]

    if mode == "decode":
        assert cache is not None
        st = cache.read("state") if is_ref else cache["state"]  # [B,h,p,n]
        decay = jnp.exp(a_log_step[:, 0])                  # [B,h]
        st = (st * decay[..., None, None]
              + jnp.einsum("bhd,bn->bhdn", xh[:, 0],
                           Bc[:, 0].astype(jnp.float32)))
        y = jnp.einsum("bn,bhdn->bhd", Cc[:, 0].astype(jnp.float32), st)
        y = y[:, None]                                     # [B,1,h,p]
        if is_ref:
            new_cache = cache.with_stack({
                "state": cache.stack["state"].at[cache.idx].set(st),
                "conv": cache.stack["conv"].at[cache.idx].set(new_hist),
            })
        else:
            new_cache = {"state": st, "conv": new_hist}
    else:
        y, final = _ssd_chunked(xh, a_log_step,
                                Bc.astype(jnp.float32),
                                Cc.astype(jnp.float32), cfg.ssm.chunk_size)
        new_cache = ({"state": final, "conv": new_hist}
                     if mode == "prefill" else None)

    # D skip connection on the (un-dt-scaled) conv output, per mamba2 ref
    y = y + (xr.reshape(Bsz, S, h, p).astype(jnp.float32)
             * params["d_skip"][:, None])
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 params["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"]), new_cache
