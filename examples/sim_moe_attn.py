"""MoE-Attention disaggregation walkthrough (§5.2) in the SuperPod
simulator: the same serving control plane, two deployments.

``deployment="colocated"`` prices every decode DP group as a monolithic
die running attention + expert FFN serially per layer (the §4.4
ping-pong chain). ``deployment="moe_attn"`` splits the pod into an
attention pool and a shared expert pool bridged by §3.3 A2E/E2A
trampolines, and prices each iteration through the Fig. 19 DP-domain
pipeline — the closed form that ``DomainPipeline.schedule()``
cross-validates.

The walkthrough shows the three effects that make the mode worth
simulating:

  1. the colocated-vs-disagg crossover: disaggregation wins at large
     batch-per-die (expert compute + trampolines hide under attention)
     and loses at small batch (per-microbatch trampoline latency and
     expert-stage launches are exposed — pipeline bubbles),
  2. pool-aware faults: a straggling or dead EXPERT die degrades every
     attention DP that dispatches to it, while an attention-die death
     stays a one-DP failover,
  3. per-layer EPLB: hot experts inflate the expert stage of exactly
     their layers; balancing claws the inflation back in both modes.

    PYTHONPATH=src python examples/sim_moe_attn.py
"""
import sys

sys.path.insert(0, "src")

from repro.sim import (FaultPlan, SimConfig, SuperPodCostModel,
                       SuperPodSim, WorkloadConfig)
from repro.configs import get_config
from repro.core.transformerless import plan_partition


def show(tag: str, rep) -> None:
    s = rep.summary
    extra = ""
    if s["deployment"] == "moe_attn":
        extra = (f"  expert_util={s['expert_pool_util']:.2f}"
                 f"  bubble={s['pipeline_bubble_fraction']:.2f}")
    print(f"{tag:>26}: tpot={s['tpot_mean_s'] * 1e3:6.1f}ms  "
          f"{s['throughput_tok_s_per_die']:6.1f} tok/s/die  "
          f"finished={s['n_finished']}/{s['n_requests']}  "
          f"failovers={s['n_failovers']}{extra}")


def main() -> None:
    cfg = get_config("deepseek-v3-671b")
    plan = plan_partition(cfg, 768)
    cost = SuperPodCostModel(cfg, plan)
    print(f"partition plan: {plan.n_attention} attention + "
          f"{plan.n_expert} expert dies, {plan.n_dp_domains} DP domains "
          f"x {plan.dp_groups_per_domain} groups (the paper's 288/480)")

    # -- 1. the crossover, straight from the cost model ----------------
    print("\ncolocated vs disaggregated decode iteration:")
    for b in (4, 16, 32, 96):
        t_col = cost.decode_iter_time(b, mean_context=1024)
        c = cost.moe_attn_decode_iter_time(b, mean_context=1024)
        who = "disagg" if c.t_iter < t_col else "colocated"
        print(f"   bpd {b:>3}: colocated {t_col * 1e3:5.1f}ms  "
              f"disagg {c.t_iter * 1e3:5.1f}ms  "
              f"bubble={c.bubble_frac:.2f}  -> {who} wins")

    # -- 2. end-to-end serving runs, both deployments ------------------
    wl = WorkloadConfig(arrival_rate=80.0, duration_s=1.0, seed=11)
    col = SimConfig(n_sim_dps=8, eplb_interval_s=0.5)
    dis = SimConfig(n_sim_dps=8, eplb_interval_s=0.5,
                    deployment="moe_attn")
    print()
    show("colocated pod", SuperPodSim(col, wl).run())
    show("moe_attn pod", SuperPodSim(dis, wl).run())

    # -- 3. pool-aware faults ------------------------------------------
    # an expert-pool die throttles 0.3s in: EVERY attention DP's MoE
    # stage stretches (the EP all-to-all has no way around it)
    show("expert-die straggler (4x)", SuperPodSim(
        dis, wl, FaultPlan(straggler_dp=2, straggler_at=0.3,
                           straggler_slowdown=4.0,
                           straggler_pool="expert")).run())
    # a dead expert die: survivors absorb its experts (capacity loss,
    # no failovers); a dead ATTENTION die stays a one-DP failover
    show("dead expert die", SuperPodSim(
        dis, wl, FaultPlan(dead_dp=1, dead_at=0.3,
                           dead_pool="expert")).run())
    show("dead attention DP", SuperPodSim(
        dis, wl, FaultPlan(dead_dp=1, dead_at=0.3)).run())

    # -- 4. hot experts + per-layer EPLB in the disagg pipeline --------
    skew = FaultPlan(expert_skew=0.8)
    off = SimConfig(n_sim_dps=8, eplb_enabled=False,
                    deployment="moe_attn")
    show("hot experts, no EPLB", SuperPodSim(off, wl, skew).run())
    show("hot experts + EPLB", SuperPodSim(dis, wl, skew).run())


if __name__ == "__main__":
    main()
