"""Quickstart: serve a smoke model end-to-end with FlowServe.

    PYTHONPATH=src python examples/quickstart.py [--arch internlm2-1.8b]

Spins up a FlowServe engine (decentralized DP groups + TE-shell), submits
a few requests, and streams tokens through the output-shortcutting path.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.serving import FlowServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--dp-groups", type=int, default=2)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-smoke")
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={cfg.param_count()/1e6:.1f}M (reduced smoke variant)")
    engine = FlowServeEngine(cfg, n_dp_groups=args.dp_groups,
                             max_batch=2, max_len=128)

    prompts = [
        "the expert dispatch routes tokens",
        "cloudmatrix has 384 chips",
        "prefill is compute bound, decode is memory bound",
    ]
    reqs = [engine.submit_text(p, args.max_new_tokens, ignore_eos=True)
            for p in prompts]
    engine.run_until_done()
    for r in reqs:
        text = engine.tokenizer.decode(r.output_tokens)
        print(f"[req {r.req_id}] ttft={r.ttft*1e3:.0f}ms "
              f"tpot={r.tpot*1e3:.1f}ms/token -> {text!r}")
    for dp in engine.dps:
        s = dp.status()
        print(f"[dp {s.dp_id}] kv_usage={s.kv_usage:.2f} "
              f"prefix_cache={len(dp.prefix_cache)} entries "
              f"gc_collections={dp.gc_ctl.collections}")
    engine.close()


if __name__ == "__main__":
    main()
