"""EPLB walkthrough (paper §4.5, Fig. 12): collect → select → place →
reconfig → rotation-balanced routing.

    PYTHONPATH=src python examples/eplb_demo.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.serving.eplb import (ExpertLoadCollector, ExpertReconfigurator,
                                build_expert_map, select_redundant_experts,
                                simulated_layer_load)


def main() -> None:
    rng = np.random.default_rng(0)
    E, NPUS, LAYERS = 64, 16, 4

    # step 1: collect token counts (the Collect kernel output)
    col = ExpertLoadCollector(LAYERS, E)
    pop = rng.zipf(1.3, size=E).astype(float)
    for _ in range(6):
        step = rng.poisson(pop[None, :] * 40, size=(LAYERS, E))
        col.record(step)
        col.end_slice()
    counts = col.token_count          # [L, E, T]
    layer0 = counts[0]
    print(f"hottest/avg load: "
          f"{layer0.sum(1).max() / layer0.sum(1).mean():.1f}x")

    # step 2: EPLB selection + placement
    chosen = select_redundant_experts(layer0, budget=8)
    base = simulated_layer_load(layer0, {e: 1 for e in range(E)})
    reps = {e: 1 for e in range(E)}
    for e in chosen:
        reps[e] += 1
    print(f"replicating experts {chosen}")
    print(f"simulated layer load: {base:.0f} -> "
          f"{simulated_layer_load(layer0, reps):.0f}")

    # steps 3+4: phased reconfig (prefetch → shadow-load → swap) +
    # rotation mapping
    em = build_expert_map(layer0, E, budget=8, n_npus=NPUS)
    swapped = []
    rc = ExpertReconfigurator(apply_fn=swapped.append,
                              bytes_per_replica=1)
    plan = rc.begin(em)
    print(f"migration: {plan.n_replica_loads} replica loads "
          f"(hottest NPU {plan.hottest_npu_loads})")
    while rc.step() != 4:
        pass
    assert swapped, "swap phase must install the new placement"
    print(f"reconfig complete in {rc.steps_to_converge} phases; "
          f"physical slots: {em.n_physical}")

    # communication-free rotation: tokens at different batch positions hit
    # different replicas of the same logical expert (Fig. 12)
    hot = chosen[0]
    pos = np.arange(8)
    phys = em.map_tokens(pos, np.full(8, hot))
    print(f"logical expert {hot} replicas {em.replicas[hot]} -> "
          f"positions 0..7 route to physical slots {phys.tolist()}")


if __name__ == "__main__":
    main()
