"""SuperPod simulator walkthrough: serve DeepSeek-V3 at 384-die scale
on your laptop, then break the pod and watch the control plane recover.

The simulator runs the REAL serving control plane (prefill scheduler,
decode load balancer, TE-shell EPLB, tiered heartbeats) over a modeled
CloudMatrix384 fabric — model execution is replaced by a roofline/XCCL
cost model, so a few virtual minutes of pod time take wall-clock
seconds and every run is byte-deterministic for a given seed.

    PYTHONPATH=src python examples/sim_superpod.py
"""
import sys

sys.path.insert(0, "src")

from repro.sim import FaultPlan, SimConfig, SuperPodSim, WorkloadConfig


def show(tag: str, rep) -> None:
    s = rep.summary
    print(f"{tag:>22}: tpot={s['tpot_mean_s'] * 1e3:6.1f}ms  "
          f"ttft_p99={s['ttft_p99_s'] * 1e3:6.0f}ms  "
          f"{s['throughput_tok_s_per_die']:6.1f} tok/s/die  "
          f"finished={s['n_finished']}/{s['n_requests']}  "
          f"failovers={s['n_failovers']}")


def main() -> None:
    sim_cfg = SimConfig(n_sim_dps=8, eplb_interval_s=0.5)
    wl = WorkloadConfig(arrival_rate=80.0, duration_s=1.0, seed=11)

    sim = SuperPodSim(sim_cfg, wl)
    print(f"partition plan: {sim.plan.n_attention} attention dies + "
          f"{sim.plan.n_expert} expert dies in {sim.plan.n_dp_domains} "
          f"DP domains (the paper's 288/480 split)")

    show("healthy pod", sim.run())

    # a die starts thermal-throttling 0.3s in: its DP group's iterations
    # stretch and the fleet p99 follows
    show("straggler die (4x)", SuperPodSim(
        sim_cfg, wl, FaultPlan(straggler_dp=2, straggler_at=0.3,
                               straggler_slowdown=4.0)).run())

    # a DP group dies: the tiered heartbeat detects it, the balancer
    # stops routing there, active requests recompute elsewhere
    show("dead DP group", SuperPodSim(
        sim_cfg, wl, FaultPlan(dead_dp=1, dead_at=0.3)).run())

    # skewed expert popularity: hot expert dies gate every decode layer
    # until EPLB replicates them away
    skew = FaultPlan(expert_skew=0.8)
    show("hot experts, no EPLB", SuperPodSim(
        SimConfig(n_sim_dps=8, eplb_enabled=False), wl, skew).run())
    show("hot experts + EPLB", SuperPodSim(sim_cfg, wl, skew).run())


if __name__ == "__main__":
    main()
