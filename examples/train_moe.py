"""End-to-end training driver: train a ~100M-param MoE for a few hundred
steps on the synthetic corpus, with checkpointing and MoE aux losses.

    PYTHONPATH=src python examples/train_moe.py [--steps 300]

(The assignment's end-to-end driver: ~100M model, a few hundred steps.)
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_moe_ckpt")
    args = ap.parse_args()

    from repro.configs import ATTN, MLP, MOE, ModelConfig, MoEConfig
    from repro.train import (AdamWConfig, DataConfig, TrainConfig, Trainer)

    # ~100M-param fine-grained MoE (deepseek-moe style, scaled down)
    cfg = ModelConfig(
        name="moe-100m", family="moe",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=4096,
        prefix_layers=((ATTN, MLP),),
        layer_pattern=((ATTN, MOE),),
        moe=MoEConfig(num_experts=8, num_shared_experts=1, top_k=2,
                      expert_d_ff=512, shared_d_ff=512,
                      capacity_factor=1.5),
    )
    print(f"params: {cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")

    tcfg = TrainConfig(
        steps=args.steps, log_every=20, ckpt_every=100,
        ckpt_dir=args.ckpt_dir,
        opt=AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps),
        data=DataConfig(seq_len=args.seq_len, global_batch=args.batch))
    tr = Trainer(cfg, tcfg)
    tr.maybe_restore()
    tr.run(on_log=lambda r: print(
        f"step {r['step']:4d}  loss {r['loss']:.4f}  nll {r['nll']:.4f}  "
        f"lb {r['moe_lb_loss']:.4f}  gnorm {r['grad_norm']:.2f}  "
        f"{r['wall_s']:.0f}s", flush=True))
    first, last = tr.history[0], tr.history[-1]
    print(f"\nnll: {first['nll']:.3f} -> {last['nll']:.3f} "
          f"over {last['step'] - first['step']} steps; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
