"""End-to-end disaggregated Prefill-Decode serving (paper §5.1).

    PYTHONPATH=src python examples/serve_disaggregated.py

Two prefill TEs (one long-capable, one on a RoCE-like fabric — the
heterogeneous 910B case) and one decode TE, connected by isolated
DistFlow instances. Requests follow the paper's 8-step workflow:
JE routing → prefill → metadata-only transfer registration → decode TE
selection → KV-usage DP routing → capacity-checked pull → transfer →
completion queues.
"""
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import DisaggregatedPD
from repro.serving.request import Request


def main() -> None:
    cfg = get_config("deepseek-moe-16b-smoke")
    print(f"serving {cfg.name}: MoE {cfg.moe.num_experts}e "
          f"top-{cfg.moe.top_k} + {cfg.moe.num_shared_experts} shared")
    pd = DisaggregatedPD(cfg, n_prefill_te=2, n_decode_te=1, dp_per_te=2,
                         max_batch=2, max_len=128,
                         prefill_fabrics=["ub", "roce"])
    reqs = [Request(prompt=p, max_new_tokens=10, ignore_eos=True)
            for p in ["disaggregate the transformer",
                      "attention is stateful, experts are stateless",
                      "trampoline forward balances the fan out",
                      "a" * 200]]   # a long one → long-capable TE
    done = pd.run_until_done(reqs)
    for r in sorted(done, key=lambda r: r.req_id):
        print(f"[req {r.req_id}] prefill_te={r.prefill_te} "
              f"decode_te={r.decode_te} dp={r.dp_group} "
              f"tokens={len(r.output_tokens)}")
    for pair, flow in pd.distflow.items():
        print(f"[distflow {pair}] fabric={flow.fabric} "
              f"bytes_moved={flow.bytes_moved/1e6:.2f}MB")
    pd.close()


if __name__ == "__main__":
    main()
